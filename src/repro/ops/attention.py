"""Decode attention and KV-cache append as registered SpuOps.

Three op kinds live here:

``kv_append``   -- quantize the new token's K/V (or MLA latent) rows and
                   scatter them into the cache at each sequence's length.
``attn_decode`` -- one-token GQA attention of the current queries against
                   the packed cache.
``mla_decode``  -- the MLA variant: a single compressed latent stream whose
                   first ``v_width`` lanes double as values.

``append + attend`` used to be two ad-hoc functions on
``core/attention_cache``; they are now planned and dispatched through the
same registry as the state update, so the paged pool (which gathers pages
into a dense :class:`~repro.core.attention_cache.KVCache`) and the
contiguous fixed-slot pool share one entry point
(:func:`attention_decode_step`), and the cost models read the ops' own
``traffic(plan)`` descriptors.

Backends: ``pallas`` is the fused MX8 decode kernel (read-only GEMV streams,
paper §6.2); ``jnp`` covers every storage format with reference semantics.
``kv_append`` is jnp-only -- it is an XLA scatter, not an SPU compute op,
but it is registered so its write traffic is accounted the same way.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.kernels import ref as _ref
from repro.kernels.mx_attention import mx_attention_decode as _attn_pallas
from repro.ops import registry
from repro.ops.base import (OPERAND_BYTES, OUTPUT_BYTES, OpPlan, SpuOp,
                            StateQuantConfig, TrafficBytes, fmt_of_state)


def _cache_row_vals(plan: OpPlan) -> int:
    """Stored values per cached token across K and V streams."""
    return plan.dim("KVH") * (plan.dim("dk") + plan.dim("dv"))


# ---------------------------------------------------------------------------
# kv_append
# ---------------------------------------------------------------------------

@registry.register
class KVAppendJnp(SpuOp):
    """Quantize + scatter n new token rows into a KV cache."""
    kind = "kv_append"
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[AC.KVCache, None]:
        k_new, v_new = inputs["k"], inputs.get("v")
        seed = inputs.get("seed", 0)
        if isinstance(cache.k, F.QuantizedTensor):
            bits = (F.sr_bits(k_new.shape, seed)
                    if plan.rounding == "stochastic" else None)
            qk = F.quantize(k_new, cache.fmt, plan.rounding, bits)
            payload = {f: AC._update_at(cache.k.payload[f], qk.payload[f],
                                        cache.lengths)
                       for f in cache.k.payload}
            nk = F.QuantizedTensor(cache.fmt, cache.k.shape, payload)
            nv = None
            if v_new is not None:
                bits_v = (F.sr_bits(v_new.shape, seed + 1)
                          if plan.rounding == "stochastic" else None)
                qv = F.quantize(v_new, cache.fmt, plan.rounding, bits_v)
                vpayload = {f: AC._update_at(cache.v.payload[f], qv.payload[f],
                                             cache.lengths)
                            for f in cache.v.payload}
                nv = F.QuantizedTensor(cache.fmt, cache.v.shape, vpayload)
        else:
            nk = AC._update_at(cache.k, k_new, cache.lengths)
            nv = (None if v_new is None
                  else AC._update_at(cache.v, v_new, cache.lengths))
        n = k_new.shape[1]
        return AC.KVCache(nk, nv, cache.lengths + n, cache.fmt, cache.v_width,
                          cache.time_axis), None

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        B, n = plan.dim("B"), plan.dim("n")
        vals = B * n * _cache_row_vals(plan)
        return TrafficBytes(state_write=vals * plan.bits_per_val / 8.0,
                            operand_read=vals * OPERAND_BYTES)


# ---------------------------------------------------------------------------
# attn_decode / mla_decode
# ---------------------------------------------------------------------------

class _AttnDecodeBase(SpuOp):
    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # score + attend stream the whole valid cache once, read-only
        B, T, H = plan.dim("B"), plan.dim("T"), plan.dim("H")
        cache = B * T * _cache_row_vals(plan) * plan.bits_per_val / 8.0
        dv_out = plan.opt("v_width") or plan.dim("dv")
        return TrafficBytes(
            state_read=cache,
            operand_read=B * H * plan.dim("dk") * OPERAND_BYTES,
            output_write=B * H * dv_out * OUTPUT_BYTES)


class _AttnDecodePallas(_AttnDecodeBase):
    """Fused decode attention over the packed MX8 cache (GQA or MLA)."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[AC.KVCache, jnp.ndarray]:
        out = _attn_pallas(inputs["q"], cache.k, cache.v, cache.lengths,
                           scale=plan.opt("scale"),
                           v_width=plan.opt("v_width"),
                           t_block=plan.opt("t_block", 128), interpret=True)
        return cache, out


class _AttnDecodeJnp(_AttnDecodeBase):
    """Reference decode attention for every storage format."""
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[AC.KVCache, jnp.ndarray]:
        q = inputs["q"]
        scale, vw = plan.opt("scale"), plan.opt("v_width")
        if isinstance(cache.k, F.QuantizedTensor):
            if cache.fmt == "mx8" and cache.v is not None:
                out = _ref.mx_attention_decode_ref(q, cache.k, cache.v,
                                                   cache.lengths, scale)
                return cache, out
            kf = F.dequantize(cache.k)
            vf = kf[..., :vw] if cache.v is None else F.dequantize(cache.v)
        else:
            kf = cache.k.astype(jnp.float32)
            vf = (kf[..., :vw] if cache.v is None
                  else cache.v.astype(jnp.float32))
        return cache, _ref.attention_decode_ref(q, kf, vf, cache.lengths, scale)


@registry.register
class AttnDecodePallas(_AttnDecodePallas):
    kind = "attn_decode"


@registry.register
class AttnDecodeJnp(_AttnDecodeJnp):
    kind = "attn_decode"


@registry.register
class MlaDecodePallas(_AttnDecodePallas):
    kind = "mla_decode"


@registry.register
class MlaDecodeJnp(_AttnDecodeJnp):
    kind = "mla_decode"


# ---------------------------------------------------------------------------
# call-site entry points
# ---------------------------------------------------------------------------

def attn_kind_of(cache) -> str:
    return "mla_decode" if cache.v_width is not None else "attn_decode"


def _layout_of(cache) -> str:
    """The container type selects the op layout: a PagedKVCache dispatches
    to the block-table-native ops, a dense KVCache to the dense ops."""
    from repro.core.paged import PagedKVCache
    return "paged" if isinstance(cache, PagedKVCache) else "dense"


def _cache_quant(cache, cfg: StateQuantConfig) -> StateQuantConfig:
    from repro.core.paged import PagedKVCache
    fmt = (cache.fmt if isinstance(cache, PagedKVCache)
           else fmt_of_state(cache.k))
    return StateQuantConfig(fmt=fmt, rounding=cfg.rounding,
                            backend=cfg.backend)


def _cache_dims(cache, n: int = 1) -> Dict[str, int]:
    from repro.core.paged import PagedKVCache
    if isinstance(cache, PagedKVCache):
        return dict(B=cache.batch, T=cache.max_len, KVH=cache.kv_heads,
                    dk=cache.dk, dv=0 if cache.v is None else cache.dv, n=n)
    B, T, KVH, dk = cache.k.shape
    dv = 0 if cache.v is None else cache.v.shape[-1]
    return dict(B=B, T=T, KVH=KVH, dk=dk, dv=dv, n=n)


def plan_attn_decode_dims(kind: str, dims: Dict[str, int],
                          cfg: StateQuantConfig, *, scale=None,
                          v_width=None, layout: str = "dense",
                          strict: bool = False) -> OpPlan:
    """Plan a decode-attention invocation from explicit dims (cost models)."""
    dims = dict(dims)
    dims.setdefault("H", dims["KVH"])
    return registry.plan(kind, dims, cfg, cfg.backend, layout=layout,
                         strict=strict, scale=scale, v_width=v_width)


def kv_append(cache, k_new: jnp.ndarray,
              v_new: Optional[jnp.ndarray], cfg: StateQuantConfig,
              seed=0):
    """Append one (or n) token(s): k_new (B, n, KVH, dk)."""
    quant = _cache_quant(cache, cfg)
    p = registry.plan("kv_append", _cache_dims(cache, n=k_new.shape[1]), quant,
                      cfg.backend, layout=_layout_of(cache))
    new_cache, _ = registry.execute(cache, {"k": k_new, "v": v_new,
                                            "seed": seed}, p)
    return new_cache


def attn_decode(cache, q: jnp.ndarray, cfg: StateQuantConfig,
                scale: Optional[float] = None,
                t_block: int = 128) -> jnp.ndarray:
    """Decode attention of current-token queries q (B,H,dk) vs the cache."""
    quant = _cache_quant(cache, cfg)
    dims = _cache_dims(cache)
    dims["H"] = q.shape[1]
    p = registry.plan(attn_kind_of(cache), dims, quant, cfg.backend,
                      layout=_layout_of(cache),
                      scale=scale, v_width=cache.v_width, t_block=t_block)
    _, out = registry.execute(cache, {"q": q}, p)
    return out


def attention_decode_step(cache, k_new: jnp.ndarray,
                          v_new: Optional[jnp.ndarray], q: jnp.ndarray,
                          cfg: StateQuantConfig, *,
                          scale: Optional[float] = None, seed=0,
                          ) -> Tuple[jnp.ndarray, AC.KVCache]:
    """One decode step: append the token's K/V, then attend.

    The single entry point for GQA and MLA; the cache container selects the
    layout (dense ``KVCache`` vs block-table ``PagedKVCache``).
    """
    cache = kv_append(cache, k_new, v_new, cfg, seed=seed)
    out = attn_decode(cache, q, cfg, scale=scale)
    return out, cache
