"""Block-table-native SPU ops: the ``layout="paged"`` registry entries.

These consume the paged containers of :mod:`repro.core.paged` directly --
the serving pool's page/slab pools plus the step's block table -- instead of
a gathered dense cache tree:

``attn_decode`` / ``mla_decode`` (pallas, mx8)
    :func:`repro.kernels.mx_paged_attention.mx_paged_attention_decode`: the
    flash grid walks ``bt[B, npg]`` via scalar prefetch, dequantizing one
    128-token page per tile straight from the shared pool.

``attn_decode`` / ``mla_decode`` (jnp, every format)
    Reference semantics for parity: gathers the block table's pages into the
    dense layout *inside the op* and runs the dense jnp reference, so paged
    logits are bit-identical to the dense-gather path by construction.  Its
    ``traffic(plan)`` still reports what a real paged read moves
    (page-granular streams), which is what the cost models consume.

``kv_append`` (pallas mx8 / jnp every format)
    Quantizes the new token's K/V rows with the *same* bits as the dense
    op (identical shapes and seed -> identical stochastic rounding) and
    writes them into their page slot in place -- ``input_output_aliases``
    on the pallas path, a one-slot ``.at[].set`` scatter on jnp.

``state_update`` (pallas mx8 / jnp every format)
    State slabs are per-request already, so the paged op reads exactly the
    ``B`` owned slab rows, runs the registered *dense* kernel on them
    (same fused ``mx_state_update``, bit-identical), and writes the rows
    back in place.

Traffic descriptors are page-granular: attention reads whole 128-token
pages (``ceil(T/128)`` of them -- a partially-filled tail page still
streams), appends write one row, state updates touch one slab row --
no full-pool gather/scatter term exists for the steady-state decode loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.core.paged import (PAGE_TOKENS, PagedKVCache, PagedState,
                              pages_for)
from repro.kernels.mx_paged_attention import (mx_paged_attention_decode,
                                              mx_paged_kv_append)
from repro.ops import registry
from repro.ops.attention import _cache_row_vals
from repro.ops.base import (OPERAND_BYTES, OUTPUT_BYTES, OpPlan, SpuOp,
                            TrafficBytes)


def _gather_stream(pool, bt: jnp.ndarray, group) -> Any:
    """Pool (P, G, 128, KVH, w) -> dense (B, npg*128, KVH, w) for one group."""
    def one(arr):
        g = arr[bt, jnp.asarray(group, jnp.int32)]     # (B, npg, 128, KVH, w)
        B, npg = g.shape[:2]
        return g.reshape((B, npg * PAGE_TOKENS) + g.shape[3:])
    if isinstance(pool, F.QuantizedTensor):
        payload = {f: one(a) for f, a in pool.payload.items()}
        B, T = payload["mantissa"].shape[:2]
        shape = (B, T) + pool.payload["mantissa"].shape[3:]
        return F.QuantizedTensor(pool.fmt, shape, payload)
    return one(pool)


def _dense_view(cache: PagedKVCache) -> AC.KVCache:
    """Materialize the block table's dense KVCache (jnp reference path)."""
    k = _gather_stream(cache.k, cache.bt, cache.group)
    v = (None if cache.v is None
         else _gather_stream(cache.v, cache.bt, cache.group))
    return AC.KVCache(k, v, cache.lengths, cache.fmt, cache.v_width)


# ---------------------------------------------------------------------------
# attn_decode / mla_decode
# ---------------------------------------------------------------------------

class _PagedAttnBase(SpuOp):
    layout = "paged"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # page-granular: every touched page streams whole, once, read-only
        B, T, H = plan.dim("B"), plan.dim("T"), plan.dim("H")
        toks = pages_for(T) * PAGE_TOKENS
        cache = B * toks * _cache_row_vals(plan) * plan.bits_per_val / 8.0
        dv_out = plan.opt("v_width") or plan.dim("dv")
        bt_bytes = B * pages_for(T) * 4.0               # the block table walk
        return TrafficBytes(
            state_read=cache,
            operand_read=B * H * plan.dim("dk") * OPERAND_BYTES + bt_bytes,
            output_write=B * H * dv_out * OUTPUT_BYTES)


class _PagedAttnPallas(_PagedAttnBase):
    """Fused paged decode attention: the grid walks the block table."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, jnp.ndarray]:
        out = mx_paged_attention_decode(
            inputs["q"], cache.k, cache.v, cache.bt, cache.group,
            cache.lengths, scale=plan.opt("scale"),
            v_width=plan.opt("v_width"), interpret=True)
        return cache, out


class _PagedAttnJnp(_PagedAttnBase):
    """Reference paged attention: gather-in-op + the dense jnp reference."""
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, jnp.ndarray]:
        dense_op = registry.get_op(plan.kind, "jnp", plan.fmt, "dense")
        _, out = dense_op.execute(_dense_view(cache), inputs, plan)
        return cache, out


@registry.register
class PagedAttnDecodePallas(_PagedAttnPallas):
    kind = "attn_decode"


@registry.register
class PagedAttnDecodeJnp(_PagedAttnJnp):
    kind = "attn_decode"


@registry.register
class PagedMlaDecodePallas(_PagedAttnPallas):
    kind = "mla_decode"


@registry.register
class PagedMlaDecodeJnp(_PagedAttnJnp):
    kind = "mla_decode"


# ---------------------------------------------------------------------------
# kv_append
# ---------------------------------------------------------------------------

class _PagedKVAppendBase(SpuOp):
    kind = "kv_append"
    layout = "paged"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # one page *slot* per row per new token -- never the whole cache
        B, n = plan.dim("B"), plan.dim("n")
        vals = B * n * _cache_row_vals(plan)
        bt_bytes = B * n * 4.0
        return TrafficBytes(state_write=vals * plan.bits_per_val / 8.0,
                            operand_read=vals * OPERAND_BYTES + bt_bytes)

    # -- shared: quantize the new rows with the dense op's exact bits ----

    def _quant_rows(self, cache: PagedKVCache, new: jnp.ndarray,
                    plan: OpPlan, seed) -> Tuple[jnp.ndarray, ...]:
        """(B, 1, KVH, d) -> payload rows ((B, KVH, w), ...) bit-identical
        to what the dense kv_append stores for the same (shape, seed)."""
        # the paged append writes exactly one page slot per row; multi-token
        # appends (chunked prefill) go through PagedStatePool.insert_prefill
        assert new.shape[1] == 1, \
            f"paged kv_append writes one token per step, got n={new.shape[1]}"
        if isinstance(cache.k, F.QuantizedTensor):
            bits = (F.sr_bits(new.shape, seed)
                    if plan.rounding == "stochastic" else None)
            q = F.quantize(new, cache.fmt, plan.rounding, bits)
            return tuple(q.payload[f][:, 0] for f in sorted(q.payload))
        return (new[:, 0],)

    def _pools_of(self, stream) -> Tuple[jnp.ndarray, ...]:
        if isinstance(stream, F.QuantizedTensor):
            return tuple(stream.payload[f] for f in sorted(stream.payload))
        return (stream,)

    def _rebuild(self, stream, pools: Tuple[jnp.ndarray, ...]):
        if isinstance(stream, F.QuantizedTensor):
            return F.QuantizedTensor(stream.fmt, stream.shape,
                                     dict(zip(sorted(stream.payload), pools)))
        return pools[0]


@registry.register
class PagedKVAppendJnp(_PagedKVAppendBase):
    """One-slot scatter into the page that owns position ``lengths``."""
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def _scatter(self, pools, rows, bt, group, lengths):
        B = bt.shape[0]
        phys = bt[jnp.arange(B), lengths // PAGE_TOKENS]
        off = lengths % PAGE_TOKENS
        grp = jnp.asarray(group, jnp.int32)
        return tuple(p.at[phys, grp, off].set(r.astype(p.dtype))
                     for p, r in zip(pools, rows))

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, None]:
        k_new, v_new = inputs["k"], inputs.get("v")
        seed = inputs.get("seed", 0)
        k_rows = self._quant_rows(cache, k_new, plan, seed)
        nk = self._rebuild(cache.k, self._scatter(
            self._pools_of(cache.k), k_rows, cache.bt, cache.group,
            cache.lengths))
        nv = cache.v
        if v_new is not None:
            v_rows = self._quant_rows(cache, v_new, plan, seed + 1)
            nv = self._rebuild(cache.v, self._scatter(
                self._pools_of(cache.v), v_rows, cache.bt, cache.group,
                cache.lengths))
        n = k_new.shape[1]
        return dataclasses.replace(cache, k=nk, v=nv,
                                   lengths=cache.lengths + n), None


@registry.register
class PagedKVAppendPallas(_PagedKVAppendBase):
    """In-place page-slot write via ``input_output_aliases``."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, None]:
        k_new, v_new = inputs["k"], inputs.get("v")
        seed = inputs.get("seed", 0)
        rows = list(self._quant_rows(cache, k_new, plan, seed))
        pools = list(self._pools_of(cache.k))
        nk_count = len(pools)
        if v_new is not None:
            rows += list(self._quant_rows(cache, v_new, plan, seed + 1))
            pools += list(self._pools_of(cache.v))
        out = mx_paged_kv_append(pools, rows, cache.bt, cache.group,
                                 cache.lengths, interpret=True)
        nk = self._rebuild(cache.k, out[:nk_count])
        nv = (cache.v if v_new is None
              else self._rebuild(cache.v, out[nk_count:]))
        n = k_new.shape[1]
        return dataclasses.replace(cache, k=nk, v=nv,
                                   lengths=cache.lengths + n), None


# ---------------------------------------------------------------------------
# state_update
# ---------------------------------------------------------------------------

class _PagedStateUpdateBase(SpuOp):
    kind = "state_update"
    layout = "paged"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # identical bytes to the dense layout: the slabs are per-request, so
        # the op touches exactly the B owned rows (read + write in place)
        dense = registry.get_op("state_update", "jnp", plan.fmt, "dense")
        return dense.traffic(plan)

    def execute(self, state: PagedState, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedState, jnp.ndarray]:
        pool, slabs = state.pool, state.slabs
        grp = jnp.asarray(state.group, jnp.int32)
        if isinstance(pool, F.QuantizedTensor):
            rows = F.QuantizedTensor(
                pool.fmt, state.shape,
                {f: a[slabs, grp] for f, a in pool.payload.items()})
        else:
            rows = pool[slabs, grp]
        dense_op = registry.get_op("state_update", self.backend, plan.fmt,
                                   "dense")
        new_rows, y = dense_op.execute(rows, inputs, plan)
        if isinstance(pool, F.QuantizedTensor):
            npool = F.QuantizedTensor(
                pool.fmt, pool.shape,
                {f: pool.payload[f].at[slabs, grp].set(new_rows.payload[f])
                 for f in pool.payload})
        else:
            npool = pool.at[slabs, grp].set(new_rows.astype(pool.dtype))
        return dataclasses.replace(state, pool=npool), y


@registry.register
class PagedStateUpdatePallas(_PagedStateUpdateBase):
    """Slab rows through the fused dense MX8 kernel, written back in place."""
    backend = "pallas"
    formats = ("mx8",)


@registry.register
class PagedStateUpdateJnp(_PagedStateUpdateBase):
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")
