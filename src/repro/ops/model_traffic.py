"""Per-model decode-op plans: the bridge from a ModelConfig to SpuOp traffic.

``decode_op_plans(cfg, batch, seq_len)`` enumerates every registered SPU op
one decode step executes for a model -- (kind, plan, count) per layer class
-- so the cost models (``analysis/roofline.py``), the serving engines'
traffic accounting, and the benchmark artifacts all derive byte counts from
the ops' own ``traffic(plan)`` descriptors instead of re-deriving per-family
dimension formulas.

The dimension extraction here intentionally matches the model zoo's own
``_m2_dims`` / ``_gla_dims`` / ``_mlstm_dims`` (``models/ssm.py``): the
plans describe exactly the states those mixers allocate (including mLSTM's
normalizer-augmented dv).  sLSTM is a vector recurrence, not a registered
SPU op, and is deliberately absent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.ops import registry
from repro.ops.base import OpPlan, TrafficBytes


@dataclasses.dataclass(frozen=True)
class OpTrafficEntry:
    """One op kind's plan and how many times a decode step runs it."""
    kind: str
    plan: OpPlan
    count: int                     # invocations per decode step (layers)

    @property
    def traffic(self) -> TrafficBytes:
        """Per-step traffic of this entry (one invocation x count)."""
        return registry.traffic(self.plan).scaled(self.count)


def _state_dims(cfg, kind: str):
    """(H, dk, dv) of one mixer's recurrent state.

    Sourced from the mixers' own dimension helpers in ``models/ssm.py``
    (imported lazily -- ssm imports repro.ops at module top) so the traffic
    plans always describe exactly the states those mixers allocate,
    including mLSTM's normalizer-augmented dv.
    """
    from repro.models import ssm as SSM
    if kind == "mamba2":
        _, H, N, P = SSM._m2_dims(cfg)
        return H, N, P
    if kind == "mlstm":
        _, H, dk, _, dv_aug = SSM._mlstm_dims(cfg)
        return H, dk, dv_aug
    # gla / retnet / hgrn2
    return SSM._gla_dims(cfg)


def decode_op_plans(cfg, batch: int, seq_len: int,
                    layout: str = "dense",
                    spec_k: int = 0) -> List[OpTrafficEntry]:
    """Every SPU op one decode step runs for ``cfg``, with layer counts.

    ``seq_len`` is the cached context length the attention ops stream.
    Backend resolution follows ``cfg.state_quant`` (same negotiation as the
    executing call sites), so the accounted op is the dispatched op.
    ``layout="paged"`` enumerates the block-table-native ops instead: their
    traffic is page-granular (whole 128-token pages stream, appends write
    one slot), which is what the paged engine and the PIM bank model score.
    ``spec_k > 0`` describes one *speculative* step at ``Kq = spec_k + 1``
    query positions: attention streams through ``spec_verify`` (one cache
    stream for all positions), appends and recurrent-state updates run once
    per position.
    """
    quant = cfg.state_quant
    Kq = spec_k + 1
    entries: List[OpTrafficEntry] = []

    def layer_count(kind: str) -> int:
        return (cfg.pattern.count(kind) * cfg.n_groups
                + cfg.prelude.count(kind))

    # -- state updates, one plan per distinct family dims --------------
    state_counts: Dict[tuple, int] = {}
    for kind in ("mamba2", "gla", "retnet", "hgrn2", "mlstm"):
        n = layer_count(kind)
        if n and cfg.ssm is not None:
            dims = _state_dims(cfg, kind)
            state_counts[dims] = state_counts.get(dims, 0) + n
    from repro.ops.state_update import plan_state_update_dims
    for (H, dk, dv), n in sorted(state_counts.items()):
        entries.append(OpTrafficEntry(
            "state_update",
            plan_state_update_dims(batch, H, dk, dv, quant, layout=layout),
            n * Kq))    # recurrent updates run once per verify position

    # -- attention decode + the token append that feeds it -------------
    from repro.ops.attention import plan_attn_decode_dims
    n_attn = layer_count("attn") + (cfg.n_groups if cfg.shared_attn else 0)
    if n_attn:
        dims = dict(B=batch, T=seq_len, KVH=cfg.n_kv_heads,
                    dk=cfg.head_dim, dv=cfg.head_dim, n=1,
                    H=cfg.n_heads)
        if spec_k > 0:
            entries.append(OpTrafficEntry(
                "spec_verify",
                registry.plan("spec_verify", dict(dims, Kq=Kq), quant,
                              quant.backend, layout=layout), n_attn))
        else:
            entries.append(OpTrafficEntry(
                "attn_decode",
                plan_attn_decode_dims("attn_decode", dims, quant,
                                      layout=layout),
                n_attn))
        entries.append(OpTrafficEntry(
            "kv_append", registry.plan("kv_append", dims, quant,
                                       quant.backend, layout=layout),
            n_attn * Kq))
    n_mla = layer_count("mla")
    if n_mla and cfg.mla is not None:
        dims = dict(B=batch, T=seq_len, KVH=1, dk=cfg.mla.cache_width,
                    dv=0, n=1, H=cfg.n_heads)
        if spec_k > 0:
            entries.append(OpTrafficEntry(
                "spec_verify",
                registry.plan("spec_verify", dict(dims, Kq=Kq), quant,
                              quant.backend, layout=layout,
                              v_width=cfg.mla.kv_lora), n_mla))
        else:
            entries.append(OpTrafficEntry(
                "mla_decode",
                plan_attn_decode_dims("mla_decode", dims, quant,
                                      v_width=cfg.mla.kv_lora, layout=layout),
                n_mla))
        entries.append(OpTrafficEntry(
            "kv_append", registry.plan("kv_append", dims, quant,
                                       quant.backend, layout=layout),
            n_mla * Kq))
    return entries


def decode_traffic_by_kind(cfg, batch: int, seq_len: int,
                           layout: str = "dense") -> Dict[str, TrafficBytes]:
    """Per-op-kind traffic of one decode step (sums entries of a kind)."""
    out: Dict[str, TrafficBytes] = {}
    for e in decode_op_plans(cfg, batch, seq_len, layout):
        out[e.kind] = out.get(e.kind, TrafficBytes()) + e.traffic
    return out
