"""The SPU operator registry: (op kind x backend x format x layout) dispatch.

Every decode-time memory-bound op registers an :class:`~repro.ops.base.SpuOp`
implementation here.  Call sites never pick a backend with ad-hoc
heuristics; they ask :func:`resolve_backend` for a capable one (preferring
the fused Pallas kernels when registered for the format) or demand an exact
quadruple with ``strict=True``, which raises a clear error listing what *is*
registered.

Op kinds
--------
``state_update``  -- generalized Eq. 2 decode step (Mamba-2 / GLA / RetNet /
                     HGRN2 / mLSTM recurrent state)
``attn_decode``   -- one-token GQA attention over a packed KV cache
``mla_decode``    -- one-token MLA attention over the compressed latent cache
``kv_append``     -- quantize + scatter new K/V (or latent) rows into a cache
``spec_verify``   -- speculative-decode verification: attention over ``Kq``
                     query positions against one cache stream (the weight and
                     page reads of a single decode step amortized over the
                     drafted tokens; ``Kq=1`` degenerates to ``attn_decode``)

Layouts
-------
``dense``  -- contiguous per-step cache trees (fixed-slot serving, tests)
``paged``  -- block-table-native page/slab pools (``repro.core.paged``):
              attention streams 128-token pages in place via scalar-prefetched
              page ids, ``kv_append`` writes one page slot in place, and
              ``state_update`` touches exactly the owned slab rows.

Extending: subclass ``SpuOp``, set ``kind``/``backend``/``formats`` (and
``layout`` for paged ops), implement ``execute`` and ``traffic``, and call
:func:`register` at import time (see ``repro/ops/state_update.py`` for the
canonical dense example and ``repro/ops/paged_ops.py`` for paged).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.ops.base import (LAYOUTS, OpPlan, SpuOp, StateQuantConfig,
                            TrafficBytes)

OP_KINDS = ("state_update", "attn_decode", "mla_decode", "kv_append",
            "spec_verify")

#: backend preference for capability negotiation ("auto" requests)
BACKEND_PREFERENCE = ("pallas", "jnp")

_REGISTRY: Dict[Tuple[str, str, str, str], SpuOp] = {}


def register(op) -> SpuOp:
    """Register one implementation under every format it supports.

    Accepts an instance or an SpuOp subclass (usable as a class decorator).
    A quadruple already owned by a *different* implementation is an error --
    silent replacement would switch dispatch and traffic accounting with no
    trace; re-registering the same class (module reload) is idempotent.
    """
    inst = op() if isinstance(op, type) else op
    if inst.kind not in OP_KINDS:
        raise ValueError(f"unknown op kind {inst.kind!r}; kinds: {OP_KINDS}")
    if inst.layout not in LAYOUTS:
        raise ValueError(
            f"unknown op layout {inst.layout!r}; layouts: {LAYOUTS}")
    for fmt in inst.formats:
        key = (inst.kind, inst.backend, fmt, inst.layout)
        cur = _REGISTRY.get(key)
        if cur is not None and (type(cur).__module__, type(cur).__qualname__) \
                != (type(inst).__module__, type(inst).__qualname__):
            raise ValueError(
                f"op quadruple {key} already registered by "
                f"{type(cur).__qualname__}; refusing to overwrite with "
                f"{type(inst).__qualname__}")
        _REGISTRY[key] = inst
    return op


def registered() -> List[Tuple[str, str, str, str]]:
    """Sorted (kind, backend, fmt, layout) quadruples currently registered."""
    return sorted(_REGISTRY)


def supports(kind: str, fmt: str, backend: str,
             layout: str = "dense") -> bool:
    return (kind, backend, fmt, layout) in _REGISTRY


def backends_for(kind: str, fmt: str, layout: str = "dense") -> List[str]:
    """Capable backends for (kind, fmt, layout), in preference order."""
    found = {b for (k, b, f, lo) in _REGISTRY
             if k == kind and f == fmt and lo == layout}
    ordered = [b for b in BACKEND_PREFERENCE if b in found]
    return ordered + sorted(found - set(ordered))


def _describe(kind: Optional[str] = None) -> str:
    rows = [t for t in registered() if kind is None or t[0] == kind]
    if not rows:
        return "(registry is empty)"
    return ", ".join(f"{k}[{b}:{f}:{lo}]" for k, b, f, lo in rows)


def resolve_backend(kind: str, fmt: str, requested: Optional[str] = None,
                    *, layout: str = "dense", strict: bool = False) -> str:
    """Capability negotiation for one (kind, fmt, layout).

    ``requested=None`` (or ``"auto"``) picks the preferred capable backend.
    A concrete ``requested`` is honored when registered; otherwise ``strict``
    raises with the full capability listing, and non-strict mode falls back
    to a capable backend (the historical behavior of the inline
    ``"pallas" if fmt == "mx8" else "jnp"`` heuristic, which this replaces).
    """
    capable = backends_for(kind, fmt, layout)
    if not capable:
        raise ValueError(
            f"no backend registered for op {kind!r} with format {fmt!r} "
            f"layout {layout!r}; registered ops: {_describe()}")
    if requested in (None, "auto"):
        return capable[0]
    if requested in capable:
        return requested
    if strict:
        raise ValueError(
            f"backend {requested!r} is not registered for op {kind!r} with "
            f"format {fmt!r} layout {layout!r} (capable: {capable}); "
            f"registered ops: {_describe(kind)}")
    return capable[0]


def get_op(kind: str, backend: str, fmt: str,
           layout: str = "dense") -> SpuOp:
    try:
        return _REGISTRY[(kind, backend, fmt, layout)]
    except KeyError:
        raise KeyError(
            f"op {kind!r} backend {backend!r} format {fmt!r} layout "
            f"{layout!r} is not registered; registered ops: "
            f"{_describe(kind)}") from None


def plan(kind: str, dims, quant: StateQuantConfig,
         backend: Optional[str] = None, *, layout: str = "dense",
         strict: bool = False, **options) -> OpPlan:
    """Resolve a backend for (kind, quant.fmt, layout) and build the plan."""
    b = resolve_backend(kind, quant.fmt, backend, layout=layout,
                        strict=strict)
    return get_op(kind, b, quant.fmt, layout).plan(dims, quant, **options)


def execute(state, inputs, p: OpPlan):
    """Dispatch one planned invocation to its registered implementation."""
    return get_op(p.kind, p.backend, p.fmt, p.layout).execute(state, inputs, p)


def traffic(p: OpPlan) -> TrafficBytes:
    """The registered op's own traffic descriptor for ``p``."""
    return get_op(p.kind, p.backend, p.fmt, p.layout).traffic(p)
