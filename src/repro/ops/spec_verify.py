"""Speculative-verify attention as a registered SpuOp (``spec_verify``).

One verify pass scores ``Kq`` query positions (the current token plus the
drafted ones) against a cache that already holds their appended K/V rows.
Query position ``j`` may attend to every cached position strictly before
its own row: with ``lengths`` counting the ``Kq`` freshly appended rows,

    position j sees  pos < lengths - (Kq - 1 - j)

so row ``j``'s output is bit-identical to the single-query ``attn_decode``
of the j-th *sequential* decode step (``Kq = 1`` degenerates exactly to
``attn_decode``).  This is the paper's bandwidth argument turned into an
op: the whole cache streams ONCE for all ``Kq`` positions -- the page reads
of one decode step amortized over the drafted tokens -- so ``traffic(plan)``
reports a single cache stream plus ``Kq``-scaled operand/output bytes, and
pimsim/roofline score the verify pass accordingly.

Backends mirror the decode-attention ops:

``pallas`` (mx8, dense + paged)
    :mod:`repro.kernels.mx_spec_attention`: the flash grid of the
    single-query kernel with the query block widened to ``Kq * G`` rows and
    a per-row causal mask; the paged variant walks the block table via
    scalar prefetch, pages streaming once for all queries.

``jnp`` (every format, dense + paged)
    Reference twin: one ``attention_decode_ref`` per query position with
    the shifted lengths, stacked.  The paged jnp op gathers the block table
    into the dense layout in-op (same ``_dense_view`` delegation as the
    paged ``attn_decode``) while still reporting page-granular traffic.

Entry points: :func:`spec_attend` (plan + dispatch one verify) and
:func:`attention_spec_step` (append the ``n`` new K/V rows with the exact
per-position seeds of ``n`` sequential ``kv_append`` calls, then verify).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.core.paged import PAGE_TOKENS, PagedKVCache, pages_for
from repro.kernels import ref as _ref
from repro.kernels.mx_spec_attention import (mx_paged_spec_attention_decode,
                                             mx_spec_attention_decode)
from repro.ops import registry
from repro.ops.attention import (_cache_dims, _cache_quant, _cache_row_vals,
                                 _layout_of, kv_append)
from repro.ops.base import (OPERAND_BYTES, OUTPUT_BYTES, OpPlan, SpuOp,
                            StateQuantConfig, TrafficBytes)


class _SpecVerifyBase(SpuOp):
    kind = "spec_verify"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # the whole valid cache streams ONCE for all Kq positions (that is
        # the point of verification); only operands and outputs scale by Kq
        B, T, H, Kq = (plan.dim("B"), plan.dim("T"), plan.dim("H"),
                       plan.dim("Kq"))
        cache = B * T * _cache_row_vals(plan) * plan.bits_per_val / 8.0
        dv_out = plan.opt("v_width") or plan.dim("dv")
        return TrafficBytes(
            state_read=cache,
            operand_read=B * Kq * H * plan.dim("dk") * OPERAND_BYTES,
            output_write=B * Kq * H * dv_out * OUTPUT_BYTES)


class _SpecVerifyJnpMixin:
    """Reference semantics: per-position single-query attention, stacked."""

    def _dense_execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                       plan: OpPlan) -> jnp.ndarray:
        q = inputs["q"]                               # (B, Kq, H, dk)
        Kq = q.shape[1]
        scale, vw = plan.opt("scale"), plan.opt("v_width")
        if isinstance(cache.k, F.QuantizedTensor):
            if cache.fmt == "mx8" and cache.v is not None:
                outs = [_ref.mx_attention_decode_ref(
                            q[:, j], cache.k, cache.v,
                            cache.lengths - (Kq - 1 - j), scale)
                        for j in range(Kq)]
                return jnp.stack(outs, axis=1)
            kf = F.dequantize(cache.k)
            vf = kf[..., :vw] if cache.v is None else F.dequantize(cache.v)
        else:
            kf = cache.k.astype(jnp.float32)
            vf = (kf[..., :vw] if cache.v is None
                  else cache.v.astype(jnp.float32))
        outs = [_ref.attention_decode_ref(q[:, j], kf, vf,
                                          cache.lengths - (Kq - 1 - j), scale)
                for j in range(Kq)]
        return jnp.stack(outs, axis=1)


@registry.register
class SpecVerifyPallas(_SpecVerifyBase):
    """Fused dense spec-verify over the packed MX8 cache (GQA or MLA)."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[AC.KVCache, jnp.ndarray]:
        out = mx_spec_attention_decode(
            inputs["q"], cache.k, cache.v, cache.lengths,
            scale=plan.opt("scale"), v_width=plan.opt("v_width"),
            t_block=plan.opt("t_block", 128), interpret=True)
        return cache, out


@registry.register
class SpecVerifyJnp(_SpecVerifyBase, _SpecVerifyJnpMixin):
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, cache: AC.KVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[AC.KVCache, jnp.ndarray]:
        return cache, self._dense_execute(cache, inputs, plan)


class _PagedSpecVerifyBase(SpuOp):
    kind = "spec_verify"
    layout = "paged"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        # page-granular single stream: every touched page streams whole,
        # once, for all Kq queries -- which is what keeps the verify pass
        # within k x the attn_decode page reads (contract RC306)
        B, T, H, Kq = (plan.dim("B"), plan.dim("T"), plan.dim("H"),
                       plan.dim("Kq"))
        toks = pages_for(T) * PAGE_TOKENS
        cache = B * toks * _cache_row_vals(plan) * plan.bits_per_val / 8.0
        dv_out = plan.opt("v_width") or plan.dim("dv")
        bt_bytes = B * pages_for(T) * 4.0              # the block table walk
        return TrafficBytes(
            state_read=cache,
            operand_read=B * Kq * H * plan.dim("dk") * OPERAND_BYTES
            + bt_bytes,
            output_write=B * Kq * H * dv_out * OUTPUT_BYTES)


@registry.register
class PagedSpecVerifyPallas(_PagedSpecVerifyBase):
    """Fused paged verify: the block-table grid, query block widened by Kq."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, jnp.ndarray]:
        out = mx_paged_spec_attention_decode(
            inputs["q"], cache.k, cache.v, cache.bt, cache.group,
            cache.lengths, scale=plan.opt("scale"),
            v_width=plan.opt("v_width"), interpret=True)
        return cache, out


@registry.register
class PagedSpecVerifyJnp(_PagedSpecVerifyBase, _SpecVerifyJnpMixin):
    """Reference paged verify: gather-in-op + the dense jnp reference."""
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, cache: PagedKVCache, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[PagedKVCache, jnp.ndarray]:
        from repro.ops.paged_ops import _dense_view
        return cache, self._dense_execute(_dense_view(cache), inputs, plan)


# ---------------------------------------------------------------------------
# call-site entry points
# ---------------------------------------------------------------------------

def spec_attend(cache, q: jnp.ndarray, cfg: StateQuantConfig,
                scale: Optional[float] = None,
                t_block: int = 128) -> jnp.ndarray:
    """Verify-attention of q (B, Kq, H, dk) against a cache whose lengths
    already count the Kq appended rows; returns (B, Kq, H, dv) f32."""
    quant = _cache_quant(cache, cfg)
    dims = _cache_dims(cache)
    dims["H"] = q.shape[2]
    dims["Kq"] = q.shape[1]
    p = registry.plan("spec_verify", dims, quant, cfg.backend,
                      layout=_layout_of(cache),
                      scale=scale, v_width=cache.v_width, t_block=t_block)
    _, out = registry.execute(cache, {"q": q}, p)
    return out


def attention_spec_step(cache, k_new: jnp.ndarray,
                        v_new: Optional[jnp.ndarray], q: jnp.ndarray,
                        cfg: StateQuantConfig, *,
                        scale: Optional[float] = None, seed=0,
                        ):
    """One speculative step: append the n new K/V rows, then verify.

    k_new/v_new are (B, n, KVH, d), q is (B, n, H, dk).  Rows append one at
    a time with seed ``seed + i`` -- every element seed in the model is
    affine in the step seed with coefficient 1, so position i's append
    quantizes with exactly the bits the i-th sequential decode step would
    have used (the greedy-exactness guarantee rests on this).
    """
    n = k_new.shape[1]
    for i in range(n):
        cache = kv_append(cache, k_new[:, i:i + 1],
                          None if v_new is None else v_new[:, i:i + 1],
                          cfg, seed=seed + i)
    out = spec_attend(cache, q, cfg, scale=scale)
    return out, cache
