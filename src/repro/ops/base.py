"""Core vocabulary of the unified SPU operator API.

Pimba's architectural claim (paper §4, Eq. 2) is that attention decode and
post-transformer state updates are the *same* memory-bound operation class,
served by one shared State-update Processing Unit.  This package mirrors
that claim in software: every decode-time memory-bound op is an
:class:`SpuOp` registered by ``(kind, backend, format)`` and invoked through
one dispatch point (``repro.ops.registry``).

The op life-cycle is split in three so that *what runs* and *what is
accounted* can never diverge:

``plan(dims, quant_cfg, **options) -> OpPlan``
    Pure metadata: captures the op kind, chosen backend, storage format,
    rounding mode and the canonical problem dimensions.  Plans are hashable
    and jit-stable; they are the unit the cost models consume.

``execute(state, inputs, plan) -> (state', out)``
    Runs the op on device.  ``state`` is the resident operand (recurrent
    state container or KV cache), ``inputs`` the per-step streamed operands.

``traffic(plan) -> TrafficBytes``
    The op's own logical DRAM traffic descriptor.  ``core/pimsim.py`` and
    ``analysis/roofline.py`` source their byte counts from here, so the
    simulator scores exactly the ops the model ran -- there is no second,
    hand-maintained byte formula to drift out of sync.

Byte accounting uses the *logical* stored bits per value
(``repro.core.formats.FORMAT_BITS``; MX8 averages 8 bits/value), matching
the paper's bandwidth arithmetic.  The software containers pad MX8 to 9
stored bits (byte-aligned mantissa + uint8 exponent/16 + uint8 micro/16);
that packing overhead is a host-representation artifact, not SPU traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.core import formats as F


class SpuDeprecationWarning(DeprecationWarning):
    """Raised by the pre-registry entry points (``repro.kernels.ops``,
    ``repro.core.state_update.state_update_step``).

    A distinct subclass so CI can run first-party tests under
    ``-W error::repro.ops.base.SpuDeprecationWarning`` without tripping on
    unrelated third-party DeprecationWarnings.
    """


@dataclasses.dataclass(frozen=True)
class StateQuantConfig:
    """How recurrent state (and KV caches) are stored.

    ``backend`` is a *request*, not a guarantee: dispatch goes through
    :func:`repro.ops.registry.resolve_backend`, which falls back to a capable
    backend when the requested one is not registered for ``(kind, fmt)``
    (e.g. the fused Pallas kernels only exist for MX8).
    """
    fmt: str = "mx8"                 # fp32|bf16|fp16|fp8_e4m3|fp8_e5m2|int8|mx8
    rounding: str = "stochastic"     # nearest|stochastic
    backend: str = "pallas"          # pallas|jnp (preference, see above)

    @property
    def quantized(self) -> bool:
        return self.fmt in ("mx8", "int8", "fp8_e4m3", "fp8_e5m2")


def fmt_bits(fmt: str) -> float:
    """Logical stored bits per value of ``fmt`` (single source of truth)."""
    return F.FORMAT_BITS[fmt]


#: accounting policy for the per-step streamed tensors, shared by every op's
#: traffic descriptor: operands (d/k/v/q, new KV rows) stream in bf16 in
#: production, results leave in f32.
OPERAND_BYTES = 2.0
OUTPUT_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class TrafficBytes:
    """Logical DRAM bytes one op invocation moves, by stream.

    ``state_read``/``state_write`` are the resident operand (recurrent state
    or KV cache) -- the memory-bound term Pimba accelerates.  ``operand_read``
    is the per-step streamed inputs (d/k/v/q), ``output_write`` the per-step
    result.  All floats: MX formats have fractional bytes per value.
    """
    state_read: float = 0.0
    state_write: float = 0.0
    operand_read: float = 0.0
    output_write: float = 0.0

    @property
    def state_total(self) -> float:
        return self.state_read + self.state_write

    @property
    def total(self) -> float:
        return (self.state_read + self.state_write
                + self.operand_read + self.output_write)

    def scaled(self, n: float) -> "TrafficBytes":
        return TrafficBytes(self.state_read * n, self.state_write * n,
                            self.operand_read * n, self.output_write * n)

    def __add__(self, o: "TrafficBytes") -> "TrafficBytes":
        return TrafficBytes(self.state_read + o.state_read,
                            self.state_write + o.state_write,
                            self.operand_read + o.operand_read,
                            self.output_write + o.output_write)


#: operand layouts an op implementation can execute against.  ``dense`` is
#: the contiguous per-step cache tree; ``paged`` is the block-table-native
#: pool layout (``repro.core.paged``) where attention walks ``bt[B, npg]``
#: page ids in place and state updates touch slab rows in place.
LAYOUTS = ("dense", "paged")


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """Immutable, hashable description of one op invocation.

    ``dims`` and ``options`` are sorted (name, value) tuples so plans can be
    dict keys and jit static arguments.  Use :meth:`dim` / :meth:`opt` to
    read them back.
    """
    kind: str
    backend: str
    fmt: str
    rounding: str
    dims: Tuple[Tuple[str, int], ...]
    options: Tuple[Tuple[str, Any], ...] = ()
    layout: str = "dense"

    def dim(self, name: str) -> int:
        for k, v in self.dims:
            if k == name:
                return v
        raise KeyError(f"plan for {self.kind} has no dim {name!r}; "
                       f"has {[k for k, _ in self.dims]}")

    def opt(self, name: str, default: Any = None) -> Any:
        for k, v in self.options:
            if k == name:
                return v
        return default

    @property
    def bits_per_val(self) -> float:
        return fmt_bits(self.fmt)


class SpuOp:
    """One (kind, backend, layout) operator implementation.

    Subclasses set ``kind``, ``backend``, ``formats`` (the storage formats
    this implementation can execute) and ``layout`` (the operand layout it
    reads -- dense cache trees or block-table paged pools); the registry
    negotiates capability over all four axes.  Implement ``execute`` and
    ``traffic``.
    """

    kind: str = ""
    backend: str = ""
    formats: Tuple[str, ...] = ()
    layout: str = "dense"

    def plan(self, dims: Mapping[str, int], quant: StateQuantConfig,
             **options) -> OpPlan:
        if quant.fmt not in self.formats:
            raise ValueError(
                f"op {self.kind!r} backend {self.backend!r} does not support "
                f"format {quant.fmt!r} (supports {self.formats})")
        return OpPlan(kind=self.kind, backend=self.backend, fmt=quant.fmt,
                      rounding=quant.rounding,
                      dims=tuple(sorted(dims.items())),
                      options=tuple(sorted(options.items())),
                      layout=self.layout)

    def execute(self, state: Any, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[Any, Any]:
        raise NotImplementedError

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        raise NotImplementedError


def fmt_of_state(state: Any) -> str:
    """Storage format of a state container (QuantizedTensor or array)."""
    if isinstance(state, F.QuantizedTensor):
        return state.fmt
    import jax.numpy as jnp
    name = {jnp.float32: "fp32", jnp.bfloat16: "bf16",
            jnp.float16: "fp16"}.get(jnp.dtype(state.dtype).type)
    if name is None:
        raise ValueError(f"unrecognized unquantized state dtype {state.dtype}")
    return name
